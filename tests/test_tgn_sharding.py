"""Vertex-state sharding rules (distributed/tgn_sharding.py): spec shapes,
divisibility degradation, capacity math, and mesh-spec parsing. Pure spec
computation — runs on a single device (the multi-device launch behavior is
pinned by tests/test_cluster.py under make test-sharded)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import mailbox, tgn
from repro.distributed import tgn_sharding as tsh


def _mesh(**sizes):
    """A mesh over logical axes backed by repeats of the one real device
    (spec-validation only — never used to launch)."""
    n = int(np.prod(list(sizes.values()))) if sizes else 1
    devs = np.asarray([jax.devices()[0]] * n).reshape(tuple(sizes.values()))
    return Mesh(devs, tuple(sizes))


def _like(n_nodes=10_000, f_mem=16):
    return jax.eval_shape(
        lambda: mailbox.init_state(mailbox.TableConfig(n_nodes=n_nodes,
                                                       f_mem=f_mem)))


def test_stacked_specs_tenant_axis():
    specs = tsh.state_specs(_mesh(tenant=8), _like())
    assert tuple(specs.memory) == ("tenant", None, None)
    assert tuple(specs.last_update) == ("tenant", None)
    assert tuple(specs.nbr_ids) == ("tenant", None, None)


def test_vertex_axis_applied_when_divisible():
    specs = tsh.state_specs(_mesh(tenant=2, vertex=2), _like())
    assert tuple(specs.memory) == ("tenant", "vertex", None)
    assert tuple(specs.mail_ts) == ("tenant", "vertex")


def test_vertex_axis_dropped_when_not_divisible():
    # V=10001 does not divide a 2-way vertex axis -> replicated V dim,
    # tenant axis kept (same degrade policy as sharding._validate)
    specs = tsh.state_specs(_mesh(tenant=2, vertex=2), _like(n_nodes=10_001))
    assert tuple(specs.memory) == ("tenant", None, None)


def test_unstacked_specs_for_single_state():
    specs = tsh.state_specs(_mesh(vertex=2), _like(), stacked=False)
    assert tuple(specs.memory) == ("vertex", None)
    assert tuple(specs.nbr_cursor) == ("vertex",)


def test_batch_and_out_specs():
    mesh = _mesh(tenant=4)
    assert all(tuple(s) == ("tenant", None) for s in tsh.batch_specs(mesh))
    out = tsh.out_specs(mesh, _like())
    assert tuple(out.emb_src) == ("tenant",)
    assert tuple(out.state.memory) == ("tenant", None, None)
    assert isinstance(out, tgn.BatchOut)


def test_tenant_axis_optional():
    # a vertex-only mesh replicates the tenant dim instead of erroring
    specs = tsh.state_specs(_mesh(vertex=2), _like())
    assert tuple(specs.memory) == (None, "vertex", None)
    assert tuple(tsh.batch_specs(_mesh(vertex=2))[0]) == (None, None)


def test_tenant_capacity_rounds_to_axis_multiple():
    mesh = _mesh(tenant=4)
    assert [tsh.tenant_capacity(n, mesh) for n in (0, 1, 4, 5, 8, 9)] == \
        [4, 4, 4, 8, 8, 12]
    # no tenant axis -> no padding
    assert tsh.tenant_capacity(3, _mesh(vertex=2)) == 3


def test_make_tenant_mesh_specs():
    m = tsh.make_tenant_mesh(1)
    assert m.axis_names == ("tenant",) and m.shape["tenant"] == 1
    m2 = tsh.make_tenant_mesh("tenant=1,vertex=1")
    assert m2.axis_names == ("tenant", "vertex")
    assert tsh.make_tenant_mesh(None).shape["tenant"] == jax.device_count()


def test_make_tenant_mesh_errors_mention_xla_flags():
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        tsh.make_tenant_mesh(jax.device_count() * 64)
    with pytest.raises(ValueError, match="bad mesh clause"):
        tsh.make_tenant_mesh("tenant:2")
    with pytest.raises(ValueError, match="duplicate mesh axis"):
        tsh.make_tenant_mesh("tenant=1,tenant=1")
    with pytest.raises(ValueError, match="bad size"):
        tsh.make_tenant_mesh("tenant=zero")


def test_make_shardings_wraps_specs():
    mesh = _mesh(tenant=2)
    sh = tsh.make_shardings(mesh, tsh.state_specs(mesh, _like()))
    assert sh.memory.spec == P("tenant", None, None)
    assert sh.memory.mesh.shape["tenant"] == 2
