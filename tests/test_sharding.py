"""Sharding rules: divisibility, ZeRO-1, cache specs, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as H


def test_spec_divisibility_fallback():
    # vocab 49155 not divisible by 16 -> embed shards d_model instead
    s = shd.spec_for("params.embed", (49155, 4096), "tp", 16)
    assert tuple(s) in (("model",), (None, "model")) or s == P(None, "model")
    # clean vocab shards normally
    s2 = shd.spec_for("params.embed", (262144, 3840), "tp", 16)
    assert tuple(s2)[0] == "model"


def test_stacked_scan_dims_padded():
    s = shd.spec_for("blocks.l0.attn.wq", (8, 3840, 4096), "tp", 16)
    assert tuple(s) == (None, None, "model")


def test_fsdp2d_two_axis():
    s = shd.spec_for("blocks.l0.mlp.w_up", (8, 6144, 32768), "fsdp2d", 16)
    assert tuple(s) == (None, "data", "model")


def test_moe_expert_parallel_when_divisible():
    s = shd.spec_for("blocks.l0.moe.w_gate", (8, 16, 6144, 10752), "fsdp2d",
                     16)
    assert tuple(s)[1] == "model"  # 16 experts -> EP
    s2 = shd.spec_for("blocks.l0.moe.w_gate", (8, 8, 6144, 32768), "fsdp2d",
                      16)
    assert tuple(s2)[1] is None and "model" in tuple(s2)  # 8 experts -> TP


def test_zero1_adds_dp_axis():
    tree = {"blocks": {"mlp": {"w_up": jnp.zeros((8, 4096, 12288))}}}
    base = shd.param_specs(tree, "tp", 16)
    z1 = shd.zero1_specs(tree, "tp", 16)
    b = tuple(base["blocks"]["mlp"]["w_up"])
    z = tuple(z1["blocks"]["mlp"]["w_up"])
    assert "data" not in b and "data" in z and "model" in z


def test_batch_spec():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    s = shd.batch_spec(mesh, 8, 2)
    assert len(tuple(s)) == 2


def test_cache_spec_seq_over_model():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    s = shd.cache_spec(FakeMesh(), (8, 128, 32768, 8, 128), 128)
    assert tuple(s)[1] == "data" and tuple(s)[2] == "model"
    # batch=1: no DP shard, seq still over model
    s1 = shd.cache_spec(FakeMesh(), (8, 1, 524288, 8, 128), 1)
    assert tuple(s1)[1] is None and tuple(s1)[2] == "model"


def test_hlo_analyzer_trip_counts():
    def scanned(x, ws):
        def step(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(step, x, ws)
        return out

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    compiled = jax.jit(scanned).lower(x, ws).compile()
    stats = H.analyze(compiled.as_text())
    want_dot = 5 * 2 * 32 * 64 * 64
    assert abs(stats["flops"] - want_dot) / want_dot < 0.02


def test_hlo_analyzer_collectives():
    from repro.launch.mesh import make_host_mesh
    # single-device: no collectives expected
    def f(x):
        return x @ x.T
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32))
    stats = H.analyze(c.compile().as_text())
    assert stats["collective_bytes"] == 0.0


def test_constrain_noop_without_rules():
    shd.set_activation_rules({})
    x = jnp.zeros((4, 8))
    y = shd.constrain(x, "carry")
    assert y.shape == x.shape
