"""Data pipeline: chronology, batching, splits, generator properties."""
import numpy as np

from repro.data import stream as S
from repro.data import temporal_graph as tgd


def test_timestamps_strictly_increasing():
    g = tgd.wikipedia_like(n_edges=2000)
    assert np.all(np.diff(g.ts) > 0)


def test_bipartite_id_ranges():
    g = tgd.wikipedia_like(n_edges=1000)
    assert g.src.max() < g.cfg.n_users
    assert g.dst.min() >= g.cfg.n_users and g.dst.max() < g.cfg.n_nodes


def test_zipf_popularity_skew():
    g = tgd.wikipedia_like(n_edges=5000)
    counts = np.bincount(g.src, minlength=g.cfg.n_users)
    top10 = np.sort(counts)[::-1][:10].sum()
    assert top10 > 0.2 * g.n_edges  # heavy head


def test_power_law_dt():
    g = tgd.wikipedia_like(n_edges=5000)
    gaps = np.diff(g.ts)
    assert np.median(gaps) < np.mean(gaps) * 0.6  # heavy tail


def test_gdelt_has_node_features():
    g = tgd.gdelt_like(n_edges=500)
    assert g.node_feats is not None and g.node_feats.shape[1] == 200
    assert g.edge_feats.shape[1] == 0


def test_fixed_count_batches_cover_stream():
    g = tgd.wikipedia_like(n_edges=505)
    seen = 0
    for b in S.fixed_count(g, 100):
        seen += int(b.valid.sum())
        assert np.all(np.diff(b.ts[b.valid]) >= 0)
    assert seen == 505


def test_time_window_batches():
    g = tgd.wikipedia_like(n_edges=500)
    total = 0
    for b in S.time_window(g, 3600.0, 128):
        n = int(b.valid.sum())
        total += n
        valid_ts = b.ts[b.valid]
        if n > 1:
            assert valid_ts[-1] - valid_ts[0] < 3600.0
    assert total == 500


def test_chronological_split_disjoint():
    g = tgd.wikipedia_like(n_edges=1000)
    tr, va, te = S.chronological_split(g)
    assert tr.stop == va.start and va.stop == te.start and te.stop == 1000


def test_negatives_in_item_range():
    g = tgd.wikipedia_like(n_edges=300)
    for b in S.fixed_count(g, 50):
        assert np.all(b.neg_dst >= g.cfg.n_users)
        assert np.all(b.neg_dst < g.cfg.n_nodes)
