"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import memory as mem_mod, time_encode as te
from repro.kernels import ops, ref


@pytest.mark.parametrize("B", [1, 5, 128, 200])
@pytest.mark.parametrize("f_mem,f_edge", [(100, 172), (32, 16), (128, 0)])
def test_gru_kernel_matches_core(B, f_mem, f_edge):
    cfg = mem_mod.GRUConfig(f_mem=f_mem, f_edge=f_edge, f_time=f_mem)
    params = mem_mod.init_gru(jax.random.key(0), cfg)
    rng = np.random.RandomState(B + f_mem)
    mail = jnp.asarray(rng.randn(B, cfg.f_mail), jnp.float32)
    s = jnp.asarray(rng.randn(B, f_mem), jnp.float32)
    want = mem_mod.gru_cell(params, mail, s)
    packed = ops.pad_gru_params(params, cfg.f_mail, f_mem)
    got = ops.gru_cell(mail, s, packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gru_kernel_extra_rows_equal_lut_path():
    cfg = mem_mod.GRUConfig(f_mem=48, f_edge=24, f_time=16)
    params = mem_mod.init_gru(jax.random.key(1), cfg)
    rng = np.random.RandomState(7)
    B = 33
    mail_raw = jnp.asarray(rng.randn(B, cfg.f_mail_raw), jnp.float32)
    time_rows = jnp.asarray(rng.randn(B, 3 * cfg.f_mem), jnp.float32)
    s = jnp.asarray(rng.randn(B, cfg.f_mem), jnp.float32)
    want = mem_mod.gru_cell_lut(params, mail_raw, time_rows, s)
    packed = ops.pad_gru_params(
        {"w_i": params["w_i"][:cfg.f_mail_raw], "w_h": params["w_h"],
         "b_i": params["b_i"], "b_h": params["b_h"]},
        cfg.f_mail_raw, cfg.f_mem)
    got = ops.gru_cell(mail_raw, s, packed, extra=time_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B", [1, 7, 300])
@pytest.mark.parametrize("dim", [100, 64])
def test_lut_kernel_matches_core(B, dim):
    tcfg = te.TimeEncoderConfig(dim=dim, n_entries=128)
    lut = te.init_lut(jax.random.key(2), tcfg)
    rng = np.random.RandomState(B)
    dt = jnp.asarray(10 ** rng.uniform(0, 7, (B,)), jnp.float32)
    want = te.lut_encode(lut, dt)
    packed = ops.pad_lut_params(lut["boundaries"], lut["table"])
    got = ops.lut_encode(dt, packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_lut_kernel_boundary_values_exact():
    """dt exactly at a boundary must land in the upper bucket (>= compare),
    in both kernel and core paths."""
    tcfg = te.TimeEncoderConfig(dim=8, n_entries=16)
    lut = te.init_lut(jax.random.key(3), tcfg,
                      dt_samples=np.linspace(1, 1000, 500))
    bounds = np.asarray(lut["boundaries"])
    dt = jnp.asarray(np.concatenate([bounds, bounds - 1e-3, [0.0, 1e9]]),
                     jnp.float32)
    want = te.lut_encode(lut, dt)
    packed = ops.pad_lut_params(lut["boundaries"], lut["table"])
    got = ops.lut_encode(dt, packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,k", [(1, 2), (37, 4), (128, 10)])
@pytest.mark.parametrize("dkv,d", [(272, 100), (48, 32)])
def test_sat_kernel_matches_ref(B, k, dkv, d):
    rng = np.random.RandomState(B * k)
    E = 128
    kv = jnp.asarray(rng.randn(B, k, dkv), jnp.float32)
    dt = jnp.asarray(10 ** rng.uniform(0, 6, (B, k)), jnp.float32)
    logits = jnp.asarray(rng.randn(B, k), jnp.float32)
    valid = jnp.asarray(rng.rand(B, k) > 0.3)
    w_v = jnp.asarray(rng.randn(dkv, d) * 0.05, jnp.float32)
    b_v = jnp.asarray(rng.randn(d) * 0.1, jnp.float32)
    folded = jnp.asarray(rng.randn(E, d) * 0.05, jnp.float32)
    bounds = jnp.sort(jnp.asarray(10 ** rng.uniform(0, 6, (E - 1,)),
                                  jnp.float32))
    packed = ops.pad_sat_params(w_v, b_v, bounds, folded)
    got = ops.sat_aggregate(kv, dt, logits, valid, packed)
    want = ref.sat_aggregate_ref(kv, dt, logits, valid.astype(jnp.float32),
                                 w_v, b_v, bounds[None, :], folded)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_sat_kernel_all_invalid_row_is_zero():
    rng = np.random.RandomState(0)
    B, k, dkv, d, E = 4, 3, 48, 32, 128
    kv = jnp.asarray(rng.randn(B, k, dkv), jnp.float32)
    dt = jnp.ones((B, k), jnp.float32)
    logits = jnp.zeros((B, k), jnp.float32)
    valid = jnp.zeros((B, k), bool)
    packed = ops.pad_sat_params(
        jnp.asarray(rng.randn(dkv, d), jnp.float32),
        jnp.zeros((d,), jnp.float32),
        jnp.sort(jnp.asarray(rng.rand(E - 1) * 100, jnp.float32)),
        jnp.asarray(rng.randn(E, d), jnp.float32))
    got = ops.sat_aggregate(kv, dt, logits, valid, packed)
    np.testing.assert_allclose(np.asarray(got), 0.0)
