"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Also the fused single-pass step kernel (kernels/fused_step.py) against the
staged-kernel composition — the equivalence contract ``use_kernels="fused"``
must keep for every registered variant (make test-kernels runs this file).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.utils
from repro.core import memory as mem_mod, pruning, time_encode as te
from repro.kernels import ops, ref
from repro.kernels import sat_aggregate as sat_mod


@pytest.mark.parametrize("B", [1, 5, 128, 200])
@pytest.mark.parametrize("f_mem,f_edge", [(100, 172), (32, 16), (128, 0)])
def test_gru_kernel_matches_core(B, f_mem, f_edge):
    cfg = mem_mod.GRUConfig(f_mem=f_mem, f_edge=f_edge, f_time=f_mem)
    params = mem_mod.init_gru(jax.random.key(0), cfg)
    rng = np.random.RandomState(B + f_mem)
    mail = jnp.asarray(rng.randn(B, cfg.f_mail), jnp.float32)
    s = jnp.asarray(rng.randn(B, f_mem), jnp.float32)
    want = mem_mod.gru_cell(params, mail, s)
    packed = ops.pad_gru_params(params, cfg.f_mail, f_mem)
    got = ops.gru_cell(mail, s, packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gru_kernel_extra_rows_equal_lut_path():
    cfg = mem_mod.GRUConfig(f_mem=48, f_edge=24, f_time=16)
    params = mem_mod.init_gru(jax.random.key(1), cfg)
    rng = np.random.RandomState(7)
    B = 33
    mail_raw = jnp.asarray(rng.randn(B, cfg.f_mail_raw), jnp.float32)
    time_rows = jnp.asarray(rng.randn(B, 3 * cfg.f_mem), jnp.float32)
    s = jnp.asarray(rng.randn(B, cfg.f_mem), jnp.float32)
    want = mem_mod.gru_cell_lut(params, mail_raw, time_rows, s)
    packed = ops.pad_gru_params(
        {"w_i": params["w_i"][:cfg.f_mail_raw], "w_h": params["w_h"],
         "b_i": params["b_i"], "b_h": params["b_h"]},
        cfg.f_mail_raw, cfg.f_mem)
    got = ops.gru_cell(mail_raw, s, packed, extra=time_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B", [1, 7, 300])
@pytest.mark.parametrize("dim", [100, 64])
def test_lut_kernel_matches_core(B, dim):
    tcfg = te.TimeEncoderConfig(dim=dim, n_entries=128)
    lut = te.init_lut(jax.random.key(2), tcfg)
    rng = np.random.RandomState(B)
    dt = jnp.asarray(10 ** rng.uniform(0, 7, (B,)), jnp.float32)
    want = te.lut_encode(lut, dt)
    packed = ops.pad_lut_params(lut["boundaries"], lut["table"])
    got = ops.lut_encode(dt, packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_lut_kernel_boundary_values_exact():
    """dt exactly at a boundary must land in the upper bucket (>= compare),
    in both kernel and core paths."""
    tcfg = te.TimeEncoderConfig(dim=8, n_entries=16)
    lut = te.init_lut(jax.random.key(3), tcfg,
                      dt_samples=np.linspace(1, 1000, 500))
    bounds = np.asarray(lut["boundaries"])
    dt = jnp.asarray(np.concatenate([bounds, bounds - 1e-3, [0.0, 1e9]]),
                     jnp.float32)
    want = te.lut_encode(lut, dt)
    packed = ops.pad_lut_params(lut["boundaries"], lut["table"])
    got = ops.lut_encode(dt, packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,k", [(1, 2), (37, 4), (128, 10)])
@pytest.mark.parametrize("dkv,d", [(272, 100), (48, 32)])
def test_sat_kernel_matches_ref(B, k, dkv, d):
    rng = np.random.RandomState(B * k)
    E = 128
    kv = jnp.asarray(rng.randn(B, k, dkv), jnp.float32)
    dt = jnp.asarray(10 ** rng.uniform(0, 6, (B, k)), jnp.float32)
    logits = jnp.asarray(rng.randn(B, k), jnp.float32)
    valid = jnp.asarray(rng.rand(B, k) > 0.3)
    w_v = jnp.asarray(rng.randn(dkv, d) * 0.05, jnp.float32)
    b_v = jnp.asarray(rng.randn(d) * 0.1, jnp.float32)
    folded = jnp.asarray(rng.randn(E, d) * 0.05, jnp.float32)
    bounds = jnp.sort(jnp.asarray(10 ** rng.uniform(0, 6, (E - 1,)),
                                  jnp.float32))
    packed = ops.pad_sat_params(w_v, b_v, bounds, folded)
    got = ops.sat_aggregate(kv, dt, logits, valid, packed)
    want = ref.sat_aggregate_ref(kv, dt, logits, valid.astype(jnp.float32),
                                 w_v, b_v, bounds[None, :], folded)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_sat_kernel_all_invalid_row_is_zero():
    rng = np.random.RandomState(0)
    B, k, dkv, d, E = 4, 3, 48, 32, 128
    kv = jnp.asarray(rng.randn(B, k, dkv), jnp.float32)
    dt = jnp.ones((B, k), jnp.float32)
    logits = jnp.zeros((B, k), jnp.float32)
    valid = jnp.zeros((B, k), bool)
    packed = ops.pad_sat_params(
        jnp.asarray(rng.randn(dkv, d), jnp.float32),
        jnp.zeros((d,), jnp.float32),
        jnp.sort(jnp.asarray(rng.rand(E - 1) * 100, jnp.float32)),
        jnp.asarray(rng.randn(E, d), jnp.float32))
    got = ops.sat_aggregate(kv, dt, logits, valid, packed)
    np.testing.assert_allclose(np.asarray(got), 0.0)


def test_neg_inf_is_single_sourced():
    """The logit mask value must have exactly one definition (utils) —
    a kernel/ref drift would silently break fused-vs-staged equivalence."""
    assert pruning.NEG_INF is repro.utils.NEG_INF
    assert ref.NEG_INF is repro.utils.NEG_INF
    assert sat_mod.NEG_INF is repro.utils.NEG_INF
    from repro.kernels import fused_step as fused_mod
    assert fused_mod.NEG_INF is repro.utils.NEG_INF


# ---------------------------------------------------------------------------
# fused single-pass step vs the staged-kernel composition
# ---------------------------------------------------------------------------

#: every registered prune budget and sampler backend the student ladder
#: serves (the score-all sat+lut row exercises k == m_r).
FUSED_VARIANTS = ("sat+lut", "sat+lut+np6", "sat+lut+np4", "sat+lut+np2",
                  "sat+lut+np4+uniform", "sat+lut+np4+reservoir")


def _fused_setup(variant, key=0, f=16, n_edges=300):
    from repro.core import pipeline as pl
    from repro.data import temporal_graph as tgd
    g = tgd.wikipedia_like(n_edges=n_edges)
    dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
                f_mem=f, f_time=f, f_emb=f, m_r=10)
    cfg = pl.variant_config(variant, **dims)
    staged = pl.build_pipeline(cfg, use_kernels=True)
    fused = pl.build_pipeline(cfg, use_kernels="fused")
    params = staged.init_params(jax.random.key(key))
    return g, staged, fused, params


def _batches(g, n, B, ragged=False):
    from repro.data import stream as stream_mod
    out = []
    for i, b in enumerate(stream_mod.fixed_count(
            g, B, window=slice(0, n * B))):
        valid = np.asarray(b.valid).copy()
        if ragged and i == 1:
            valid[B // 2:] = False        # ragged round: half padding
        out.append(tuple(jnp.asarray(x) for x in
                         (b.src, b.dst, b.eid, b.ts, valid)))
    return out


@pytest.mark.parametrize("variant", FUSED_VARIANTS)
def test_fused_step_matches_staged_trajectory(variant):
    """The one-launch fused step reproduces the staged-kernel trajectory
    (state AND embeddings AND distill views) within the staged kernels'
    own tolerances, for every prune budget / sampler backend — including
    a ragged round whose padding rows must commit nothing."""
    g, staged, fused, params = _fused_setup(variant)
    ef = jnp.asarray(g.edge_feats)
    ss, sf = staged.init_state(), fused.init_state()
    assert fused.tier == "fused" and fused.stages.fused is not None
    for b in _batches(g, 4, 30, ragged=True):
        os_ = staged.step_fn(params, ss, b, ef)
        of_ = fused.step_fn(params, sf, b, ef)
        ss, sf = os_.state, of_.state
        m = np.asarray(b[4])[:, None]
        np.testing.assert_allclose(
            np.asarray((os_.emb_src - of_.emb_src)) * m, 0.0, atol=2e-5)
        np.testing.assert_allclose(
            np.asarray((os_.emb_dst - of_.emb_dst)) * m, 0.0, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(os_.nbr_valid),
                                      np.asarray(of_.nbr_valid))
        np.testing.assert_allclose(np.asarray(os_.attn_logits),
                                   np.asarray(of_.attn_logits), atol=1e-5)
        for field in ("memory", "mail", "last_update", "mail_ts",
                      "mail_valid", "nbr_ids", "nbr_ts", "nbr_eid",
                      "nbr_cursor"):
            np.testing.assert_allclose(
                np.asarray(getattr(ss, field)),
                np.asarray(getattr(sf, field)), atol=2e-5,
                err_msg=f"{variant}:{field}")


def test_fused_step_all_invalid_batch_is_bitwise_noop():
    """A fully-masked batch (idle tenant) through the fused launch leaves
    the vertex state bitwise untouched — the idle-masking contract every
    serving layer relies on."""
    g, staged, fused, params = _fused_setup("sat+lut+np4", key=3)
    ef = jnp.asarray(g.edge_feats)
    state = fused.init_state()
    for b in _batches(g, 2, 25):
        state = fused.step_fn(params, state, b, ef).state
    B = 13
    zi = jnp.zeros((B,), jnp.int32)
    bad = (zi, zi, zi, jnp.zeros((B,), jnp.float32), jnp.zeros((B,), bool))
    out = fused.step_fn(params, state, bad, ef)
    for f in state._fields:
        np.testing.assert_array_equal(np.asarray(getattr(state, f)),
                                      np.asarray(getattr(out.state, f)),
                                      err_msg=f)


def test_fused_step_is_one_kernel_launch():
    """The post-prune datapath is exactly ONE pallas launch per step under
    the fused tier; the staged tier pays one per unit (LUT + GRU + SAT)."""
    g, staged, fused, params = _fused_setup("sat+lut+np4", key=1)
    ef = jnp.asarray(g.edge_feats)
    b = _batches(g, 1, 20)[0]
    aux_s, aux_f = staged.prepare(params), fused.prepare(params)

    ops.reset_launch_count()
    jax.jit(lambda s: staged.step(params, aux_s, s, b, ef)).lower(
        staged.init_state())
    assert ops.launch_count() == 3
    ops.reset_launch_count()
    jax.jit(lambda s: fused.step(params, aux_f, s, b, ef)).lower(
        fused.init_state())
    assert ops.launch_count() == 1
