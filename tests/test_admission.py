"""Property-based admission tests: the CapacityLadder invariants every
zero-recompile guarantee rests on (headroom, monotonicity, geometric
growth past the top class) and AdmissionController audit-log consistency
under random attach/detach sequences.

Runs under real hypothesis when installed, else the deterministic
``tests/_vendor`` shim (conftest.py wires it up) — strategies are kept
inside the shim's supported surface (integers/tuples/lists/sampled_from,
zero-arg ``@given`` wrappers, so no pytest fixtures in property tests).
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.admission import AdmissionController, CapacityLadder

# ---------------------------------------------------------------------------
# CapacityLadder: pure invariants
# ---------------------------------------------------------------------------


@given(st.integers(min_value=0, max_value=500),
       st.integers(min_value=1, max_value=5))
def test_capacity_always_leaves_headroom(n, headroom):
    """capacity_for(n) > n strictly: immediately after ANY relayout there
    are at least ``headroom`` spare slots, so the next attach is
    guaranteed fast-path."""
    lad = CapacityLadder(headroom=headroom)
    cap = lad.capacity_for(n)
    assert cap >= n + headroom > n


@given(st.integers(min_value=0, max_value=300),
       st.integers(min_value=0, max_value=300))
def test_capacity_is_monotone(a, b):
    """More tenants never need fewer rows (growth never shrinks a lane
    out from under its residents)."""
    lad = CapacityLadder()
    lo, hi = sorted((a, b))
    assert lad.capacity_for(lo) <= lad.capacity_for(hi)


@given(st.integers(min_value=0, max_value=4096))
def test_capacity_is_a_ladder_class_or_doubling(n):
    """Every capacity is an explicit class, or the top class doubled k
    times (geometric growth past the ladder) — and it is MINIMAL: the
    next class down would not fit n + headroom."""
    lad = CapacityLadder()
    cap = lad.capacity_for(n)
    need = max(n + lad.headroom, lad.classes[0])
    top = lad.classes[-1]
    if cap <= top:
        assert cap in lad.classes
        smaller = [c for c in lad.classes if c < cap]
        if smaller:
            assert smaller[-1] < need          # minimality within the ladder
    else:
        c = cap
        while c > top:
            assert c % 2 == 0
            c //= 2
        assert c == top
        assert cap // 2 < need                 # minimality past the top


@given(st.integers(min_value=0, max_value=128))
def test_custom_ladder_respects_its_classes(n):
    lad = CapacityLadder(classes=(3, 7, 20), headroom=2)
    cap = lad.capacity_for(n)
    assert cap >= max(n + 2, 3)
    if cap <= 20:
        assert cap in (3, 7, 20)


def test_invalid_ladders_are_rejected():
    with pytest.raises(ValueError, match="strictly increasing"):
        CapacityLadder(classes=(4, 2))
    with pytest.raises(ValueError, match="strictly increasing"):
        CapacityLadder(classes=(2, 2, 4))
    with pytest.raises(ValueError, match="strictly increasing"):
        CapacityLadder(classes=())
    with pytest.raises(ValueError, match="headroom"):
        CapacityLadder(headroom=0)


# ---------------------------------------------------------------------------
# AdmissionController: audit-log consistency under random sequences
# ---------------------------------------------------------------------------

_VARIANTS = ("sat+lut+np4", "sat+lut+np2", "sat+lut+np4+uniform")
_SETUP: dict = {}


def _fresh_manager():
    """A reserve-enabled SessionManager over a cached tiny graph (module
    cache, not a fixture: the shim's @given wrappers take zero args)."""
    import jax
    import jax.numpy as jnp

    from repro.core import pipeline as pl, tgn
    from repro.data import temporal_graph as tgd
    from repro.serving.session import SessionManager

    if not _SETUP:
        g = tgd.wikipedia_like(n_edges=200)
        cfg = pl.variant_config(
            "sat+lut+np4", n_nodes=g.cfg.n_nodes, n_edges=g.n_edges,
            f_edge=172, f_mem=8, f_time=8, f_emb=8, m_r=10)
        _SETUP.update(cfg=cfg,
                      params=tgn.init_params(jax.random.key(0), cfg),
                      ef=jnp.asarray(g.edge_feats))
    return SessionManager(_SETUP["params"], _SETUP["ef"],
                          model=_SETUP["cfg"], reserve=CapacityLadder())


@settings(max_examples=8)
@given(st.lists(st.tuples(st.sampled_from(("attach", "detach")),
                          st.integers(min_value=0, max_value=2)),
                min_size=1, max_size=10))
def test_audit_log_consistent_under_random_sequences(ops):
    """Whatever the admission sequence: one log record per operation,
    ``fast`` is exactly ¬(relayout ∨ new_cohort), sizes/capacities in the
    record match the live cohort, every capacity respects the ladder's
    headroom contract, and the attach/detach ledger balances the live
    tenant count."""
    mgr = _fresh_manager()
    adm = AdmissionController(mgr)
    ladder = mgr.reserve
    live: list = []
    performed = 0
    for op, i in ops:
        if op == "attach":
            tid = adm.attach(_VARIANTS[i])
            live.append(tid)
            rec = adm.log[-1]
            assert rec.action == "attach" and rec.tid == tid
            cohort = mgr.cohort_of(tid)
            assert rec.size == cohort.size
            assert rec.capacity == cohort.capacity
            # a relayout lands on the ladder class (headroom restored);
            # a fast attach fits within the existing class
            if rec.relayout or rec.new_cohort:
                assert rec.capacity == ladder.capacity_for(rec.size)
            else:
                assert rec.capacity >= rec.size
        elif live:
            tid = live.pop(i % len(live))
            rec = adm.detach(tid)
            assert rec.action == "detach" and rec.tid == tid
            assert rec.fast and not rec.relayout   # detach idles the slot
        else:
            continue                     # detach with nobody live: no-op
        performed += 1
        assert len(adm.log) == performed     # exactly one record per op
        assert len(mgr.tenants) == len(live)
    # the ledger balances: attaches - detaches == live tenants
    n_att = sum(1 for a in adm.log if a.action == "attach")
    n_det = sum(1 for a in adm.log if a.action == "detach")
    assert n_att - n_det == len(live) == len(mgr.tenants)
    s = adm.stats()
    assert s["admissions"] == len(adm.log) == performed
    assert s["fast"] == sum(1 for a in adm.log if a.fast)
    assert s["relayouts"] == sum(1 for a in adm.log if a.relayout)
    assert sum(c["size"] for c in s["cohorts"]) == len(live)
    for c in s["cohorts"]:
        assert 0 <= c["size"] <= c["capacity"]


@settings(max_examples=4)
@given(st.integers(min_value=1, max_value=9))
def test_relayout_cadence_is_logarithmic(n):
    """Ramping one cohort 0->n tenants relays out only at class
    exhaustion: every non-relayout attach after the first is fast, and
    the relayout count matches the ladder crossings exactly."""
    mgr = _fresh_manager()
    adm = AdmissionController(mgr)
    for _ in range(n):
        adm.attach(_VARIANTS[0])
    attaches = [a for a in adm.log if a.action == "attach"]
    ladder = mgr.reserve
    # relayouts are LAZY: one when the ramp first exceeds the current
    # class (the first attach creates the lane), never before
    cap, slow = 0, 0
    for k in range(1, n + 1):
        if k > cap:
            cap = ladder.capacity_for(k)
            slow += 1
    assert sum(1 for a in attaches if a.relayout or a.new_cohort) == slow
    assert sum(1 for a in attaches if a.fast) == n - slow
