"""Minimal deterministic fallback for the ``hypothesis`` API surface the
test suite uses, activated by tests/conftest.py only when the real
hypothesis package is not installed (the CI image may lack it).

Semantics: ``@given(...)`` replays ``max_examples`` pseudo-random examples
drawn from a RandomState seeded by the test name — deterministic across
runs, no shrinking, no database. Install real hypothesis
(requirements-dev.txt) for proper property-based testing.
"""
from __future__ import annotations

import zlib

import numpy as np

__version__ = "0.0-repro-fallback"
_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def flatmap(self, f):
        return _Strategy(lambda rng: f(self._draw(rng))._draw(rng))

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred, _tries: int = 1000):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return _Strategy(draw)


class _Strategies:
    """Stand-in for ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(
            lambda rng: int(rng.randint(min_value, max_value + 1)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.rand() < 0.5))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, allow_nan=True,
               allow_infinity=None, width=64):
        lo, hi = float(min_value), float(max_value)

        def draw(rng):
            # mix uniform draws with boundary values so edge cases appear
            u = rng.rand()
            if u < 0.05:
                return lo
            if u < 0.10:
                return hi
            return float(lo + (hi - lo) * rng.rand())
        return _Strategy(draw)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.randint(min_size, max_size + 1))
            return [elements._draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elems):
        return _Strategy(lambda rng: tuple(e._draw(rng) for e in elems))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.randint(len(seq)))])

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)


strategies = _Strategies()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(f):
        f._shim_max_examples = max_examples
        return f
    return deco


def given(*strats, **kw_strats):
    def deco(f):
        # Deliberately a ZERO-arg wrapper (no functools.wraps): pytest must
        # not mistake the strategy-supplied parameters for fixtures.
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(f, "_shim_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = np.random.RandomState(
                zlib.crc32(f.__qualname__.encode()) % (2 ** 31))
            for _ in range(n):
                args = tuple(s._draw(rng) for s in strats)
                kwargs = {k: s._draw(rng) for k, s in kw_strats.items()}
                f(*args, **kwargs)
        wrapper.__name__ = f.__name__
        wrapper.__qualname__ = f.__qualname__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        wrapper._shim_max_examples = getattr(f, "_shim_max_examples",
                                             _DEFAULT_MAX_EXAMPLES)
        return wrapper
    return deco


def assume(condition) -> bool:
    if not condition:
        raise ValueError("assume() failed (fallback shim cannot retry)")
    return True


class example:  # @example decorator: ignored by the fallback
    def __init__(self, *a, **kw):
        pass

    def __call__(self, f):
        return f
