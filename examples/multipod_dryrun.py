"""Multi-pod dry-run example: compile one (arch x shape) cell on the
production 2-pod x 256-chip mesh with 512 placeholder devices and print the
roofline decomposition.

    python examples/multipod_dryrun.py            # note: NOT via -m repro...
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell

result = run_cell("gemma3_12b", "decode_32k", multi_pod=True)
print(json.dumps({k: v for k, v in result.items()
                  if k not in ("per_device",)}, indent=2))
print("collectives:", result["per_device"]["collectives_by_op"])
