"""Real-time streaming inference: the paper's Fig.-5-right experiment.

Processes a temporal-graph stream in wall-clock windows through the
streaming engine and reports per-window latency — the production deployment
scenario (fraud screening on incoming transactions etc.). The engine is a
thin session over a pipeline-registry variant; swap the variant string to
serve any Table-II row (including the "teacher" baseline).

    PYTHONPATH=src python examples/streaming_inference.py
"""
import jax
import numpy as np

from repro.core import tgn
from repro.core.pipeline import variant_config
from repro.data import stream, temporal_graph as tgd
from repro.serving.engine import StreamingEngine

g = tgd.reddit_like(n_edges=4000)
dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
            f_mem=32, f_time=32, f_emb=32, m_r=10)
cfg = variant_config("sat+lut+np4", **dims)
params = tgn.init_params(jax.random.key(0), cfg)
engine = StreamingEngine.from_variant("sat+lut+np4", params,
                                      jax.numpy.asarray(g.edge_feats),
                                      **dims)
print("stages:", engine.describe())

# 15-minute windows, capped at 256 edges per window
for batch, (h_src, h_dst) in engine.run(stream.time_window(g, 900.0, 256)):
    pass

s = engine.summary()
print(f"windows processed : {s['batches']}")
print(f"mean latency      : {s['mean_latency_ms']:.2f} ms")
print(f"p99 latency       : {s['p99_latency_ms']:.2f} ms")
print(f"mean H2D transfer : {s['mean_h2d_ms']:.3f} ms")
print(f"throughput        : {s['throughput_eps']:.0f} edges/s")

lat = np.array([m["latency_s"] for m in engine.metrics[1:]]) * 1e3
print(f"latency histogram (ms): min={lat.min():.2f} med={np.median(lat):.2f}"
      f" max={lat.max():.2f}")
