"""Generate tokens with every assigned architecture (reduced configs):
demonstrates the uniform family adapter + KV/ring/SSM/LRU cache handling.

    PYTHONPATH=src python examples/arch_zoo_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm_common
from repro.serving import lm_serve

prompts = jnp.asarray(np.random.RandomState(0).randint(0, 256, (2, 6)),
                      jnp.int32)
for arch in configs.all_archs():
    cfg = configs.get(arch).smoke_config()
    params = lm_common.init_params(jax.random.key(0), cfg)
    out = lm_serve.generate(params, cfg, prompts % cfg.vocab,
                            lm_serve.ServeConfig(max_new_tokens=8))
    print(f"{arch:22s} tokens={tuple(out['tokens'].shape)} "
          f"decode={out['decode_s_per_tok']*1e3:6.2f} ms/tok")
