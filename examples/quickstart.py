"""Quickstart: the paper's co-design in ~40 lines.

Builds a synthetic temporal graph, trains the TGN-attn teacher for one
epoch, distills the SAT+LUT+NP(4) student, and streams inference through
the variant-agnostic engine (Pallas kernels, prune-then-fetch, LUT time
encoder). Model variants come from the core.pipeline registry.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core.pipeline import variant_config
from repro.data import stream, temporal_graph as tgd
from repro.serving.engine import EngineConfig, StreamingEngine
from repro.training import tgn_trainer as TT

# 1. data: Wikipedia-like bipartite interaction stream
g = tgd.wikipedia_like(n_edges=3000)
base = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
            f_mem=32, f_time=32, f_emb=32, m_r=10)

# 2. teacher: vanilla temporal attention + cosine time encoder
teacher_cfg = variant_config("teacher", **base)
tcfg = TT.TGNTrainConfig(batch_size=100, epochs=1)
teacher, _ = TT.train_teacher(g, teacher_cfg, tcfg)
tr, va, te = stream.chronological_split(g)
ap_t = TT.evaluate_ap(teacher, teacher_cfg, g, va, warm_window=tr)
print(f"teacher AP: {ap_t:.4f}")

# 3. student: SAT + LUT + neighbor pruning (k=4), distilled (Eq. 17)
student_cfg = variant_config("sat+lut+np4", **base)
student, _ = TT.distill_student(g, teacher, teacher_cfg, student_cfg, tcfg)
ap_s = TT.evaluate_ap(student, student_cfg, g, va, warm_window=tr)
print(f"student AP: {ap_s:.4f} (diff {ap_s - ap_t:+.4f})")

# 4. optimized streaming inference (the paper's accelerator dataflow);
#    the SAME engine serves the teacher: EngineConfig(model=teacher_cfg)
engine = StreamingEngine(EngineConfig(model=student_cfg), student,
                         jax.numpy.asarray(g.edge_feats))
print("engine stages:", engine.describe())
for _batch, _embs in engine.run(stream.fixed_count(g, 200)):
    pass
print("engine:", engine.summary())
