"""End-to-end LM training driver example: pretrain a ~100M-parameter dense
transformer for a few hundred steps on synthetic tokens, with fault-tolerant
checkpointing — kill this script at any point and rerun: it resumes from the
newest valid checkpoint with bitwise-identical results (deterministic data
order; see tests/test_checkpoint.py::test_lm_restart_determinism).

    PYTHONPATH=src python examples/lm_pretrain.py [--steps 200]
"""
import sys

sys.argv = [sys.argv[0], "--mode", "lm", "--preset", "100m",
            "--steps", "200", "--batch", "4", "--seq", "256",
            "--ckpt", "/tmp/repro_lm100m", "--ckpt-every", "50",
            "--log-every", "10"] + sys.argv[1:]

from repro.launch.train import main

if __name__ == "__main__":
    main()
