"""Multi-tenant streaming serving: many edge streams, one parameter set.

Four tenants — think per-region transaction feeds — share one SessionManager.
Two run the paper's NP(M) student, one samples neighbors uniformly, one with
a time-decayed reservoir (the sampler-backend axis of the variant registry).
Same-variant tenants form a cohort, and the WHOLE mixed-cohort round is ONE
coalesced device launch (pipeline.CoalescedRound) fed by one in-place-staged
host transfer; per-tenant trajectories are bitwise-identical to running each
stream through its own StreamingEngine.

    PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import jax
import jax.numpy as jnp

from repro.core import tgn
from repro.core.pipeline import variant_config
from repro.data import stream, temporal_graph as tgd
from repro.serving.session import SessionManager

g = tgd.reddit_like(n_edges=4000)
dims = dict(n_nodes=g.cfg.n_nodes, n_edges=g.n_edges, f_edge=172,
            f_mem=32, f_time=32, f_emb=32, m_r=10)
cfg = variant_config("sat+lut+np4", **dims)
params = tgn.init_params(jax.random.key(0), cfg)

mgr = SessionManager(params, jnp.asarray(g.edge_feats), model=cfg)
tenants = {
    mgr.add_tenant(name="emea"): "sat+lut+np4",
    mgr.add_tenant(name="amer"): "sat+lut+np4",
    mgr.add_tenant("sat+lut+np4+uniform", name="apac"): "uniform sampler",
    mgr.add_tenant("sat+lut+np4+reservoir", name="lab",
                   reservoir_tau=3600.0): "reservoir sampler",
}
print("cohorts:")
for variant, info in mgr.describe().items():
    print(f"  {variant:24s} tenants={info['tenants']} "
          f"sampler={info['sampler']}")

# each tenant replays its own slice of the stream (independent feeds)
streams = {tid: stream.fixed_count(g, 200, window=slice(800 * i, 800 * (i+1)))
           for i, tid in enumerate(tenants)}
edges = {tid: 0 for tid in tenants}
for batches, outs in mgr.run(streams):
    for tid, out in outs.items():
        edges[tid] += int(batches[tid].valid.sum())

s = mgr.summary()
print(f"\nrounds            : {s['rounds']}")
print(f"tenants / cohorts : {s['tenants']} / {s['cohorts']}")
print(f"mean round        : {s['mean_round_ms']:.2f} ms "
      f"({mgr.metrics[-1]['launches']} launches/round)")
print(f"aggregate thpt    : {s['throughput_eps']:.0f} edges/s")
print("\nper-tenant:")
for tid in tenants:
    mem = mgr.state_of(tid).memory
    print(f"  {tid:5s} edges={edges[tid]:5d} "
          f"touched-vertices={int((jnp.abs(mem).sum(axis=1) > 0).sum()):6d}")
